"""Kernel micro-benchmarks: jnp production paths (wall time on this CPU) and
Pallas kernels in interpret mode (correctness-path latency; real TPU numbers
come from the roofline projection in EXPERIMENTS.md §Perf)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit


def _bench(fn, *args, iters=3):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
        jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def run():
    key = jax.random.PRNGKey(0)

    # flash attention (jnp custom-vjp production path)
    from repro.models.attention import flash_attention_jnp
    B, S, H, D = 1, 1024, 4, 64
    q, k, v = (jax.random.normal(jax.random.fold_in(key, i), (B, S, H, D),
                                 jnp.float32).astype(jnp.bfloat16)
               for i in range(3))
    f = jax.jit(lambda q, k, v: flash_attention_jnp(q, k, v, q_chunk=256,
                                                    kv_chunk=256))
    us = _bench(f, q, k, v)
    flops = 4 * B * S * S * H * D
    emit("kernel/flash_jnp S=1024", us, f"{flops / (us / 1e6) / 1e9:.1f}GFLOP/s")

    # ssd chunked (jnp production path)
    from repro.models.ssm import ssd_chunked
    b, s, h, p, n = 1, 1024, 8, 64, 64
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (b, s, h, p), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.5)
    Bm = jax.random.normal(ks[3], (b, s, n)) * 0.3
    Cm = jax.random.normal(ks[4], (b, s, n)) * 0.3
    Dv = jnp.ones((h,))
    g = jax.jit(lambda *a: ssd_chunked(*a, chunk=128)[0])
    us = _bench(g, x, dt, A, Bm, Cm, Dv)
    emit("kernel/ssd_jnp S=1024", us,
         f"{b * s * h * p * n * 6 / (us / 1e6) / 1e9:.1f}GFLOP/s")

    # deposit (jnp oracle vs pallas-interpret)
    from repro.kernels.deposit import ops as dops
    from repro.pic.grid import deposit_cic
    N, C = 1 << 16, 1024
    xs = jax.random.uniform(key, (N,), jnp.float32)
    w = jnp.ones((N,), jnp.float32)
    al = jnp.ones((N,), jnp.float32)
    us = _bench(jax.jit(lambda *a: deposit_cic(*a, C, 1.0 / C)), xs, w, al)
    emit("kernel/deposit_jnp N=65536", us, f"{N / us:.0f}particles/us")

    # bitshuffle host path (used by the blosc codec)
    from repro.core.compression import byte_shuffle
    buf = np.random.default_rng(0).bytes(8 << 20)
    t0 = time.perf_counter()
    byte_shuffle(buf, 4)
    us = (time.perf_counter() - t0) * 1e6
    emit("kernel/byte_shuffle 8MiB", us,
         f"{len(buf) / (us / 1e6) / 2**30:.2f}GiB/s")


if __name__ == "__main__":
    run()
