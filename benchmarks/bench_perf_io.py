"""§Perf hillclimb C — the paper's own technique, measured.

Fixed workload: 64 ranks x 1 MiB x 4 steps (256 MiB of smooth float data,
checkpoint-like). Each rung applies one optimization on top of the previous
and reports wall throughput + effective (post-compression) storage rate.

    PYTHONPATH=src python -m benchmarks.bench_perf_io
"""
from __future__ import annotations

import time

from benchmarks.common import GiB, emit, pic_payload, tmp_io_dir
from repro.core.bp_engine import BpWriter, EngineConfig
from repro.core.darshan import MONITOR
from repro.core.striping import StripeConfig

N_RANKS = 64
BYTES_PER_RANK = 1 * 1024 * 1024
STEPS = 4


def _run(cfg: EngineConfig, *, reps: int = 3) -> dict:
    best = None
    payloads = [pic_payload(r, BYTES_PER_RANK)["particles"]
                for r in range(N_RANKS)]
    for _ in range(reps):
        MONITOR.reset()
        with tmp_io_dir() as d:
            t0 = time.perf_counter()
            w = BpWriter(d / "s.bp4", N_RANKS, cfg)
            total = 0
            for s in range(STEPS):
                w.begin_step(s)
                for r, arr in enumerate(payloads):
                    total += arr.nbytes
                    w.put("p/x", arr, global_shape=(arr.size * N_RANKS,),
                          offset=(arr.size * r,), rank=r)
                w.end_step()
            w.close()
            dt = time.perf_counter() - t0
            stored = MONITOR.report()["total"]["POSIX_BYTES_WRITTEN"]
        row = {"dt": dt, "thr": total / dt / GiB, "stored": stored,
               "ratio": total / max(stored, 1)}
        if best is None or row["dt"] < best["dt"]:
            best = row
    return best


RUNGS = [
    ("r0 baseline M=1 w=1 none", EngineConfig(aggregators=1, workers=1)),
    ("r1 aggregation M=4 w=4", EngineConfig(aggregators=4, workers=4)),
    ("r2 aggregation M=8 w=8", EngineConfig(aggregators=8, workers=8)),
    ("r3 blosc (shuffle+lz1)", EngineConfig(aggregators=4, workers=4,
                                            codec="blosc")),
    ("r4 blosc 4MiB blocks", EngineConfig(aggregators=4, workers=4,
                                          codec="blosc",
                                          compression_block=4 * 1024 * 1024)),
    ("r5 blosc + striping 4x1MiB", EngineConfig(
        aggregators=4, workers=4, codec="blosc",
        stripe=StripeConfig(4, 1024 * 1024), n_osts=8)),
]


def run():
    for name, cfg in RUNGS:
        r = _run(cfg)
        emit(f"perf_io/{name}", r["dt"] * 1e6 / STEPS,
             f"{r['thr']:.3f}GiB/s ratio={r['ratio']:.2f} "
             f"effective={r['thr'] * r['ratio']:.3f}GiB/s")


if __name__ == "__main__":
    run()
