"""Repack throughput + correctness: W -> W' re-aggregation end to end.

The elastic-restart story measured: a series written at W aggregators is
rewritten at W' (and optionally recompressed) by `repro.tools.jbprepack`,
then verified BYTE-EQUIVALENT under the reader. Emits repack throughput
with serial vs ReaderPool-parallel chunk reads — the maintenance pass is
itself a consumer of the parallel read plane.

    PYTHONPATH=src python benchmarks/bench_repack.py
"""
from __future__ import annotations

from benchmarks.common import MiB, Timer, emit, pic_payload, tmp_io_dir
from repro.core.bp_engine import BpWriter, EngineConfig
from repro.tools.jbprepack import repack, verify_equivalent


def _write_series(path, *, n_ranks, bytes_per_rank, steps, codec, w):
    cfg = EngineConfig(aggregators=w, codec=codec, workers=4)
    wr = BpWriter(path, n_ranks, cfg)
    payloads = [pic_payload(r, bytes_per_rank)["particles"]
                for r in range(n_ranks)]
    n = payloads[0].size
    for s in range(steps):
        wr.begin_step(s)
        for r, arr in enumerate(payloads):
            wr.put("particles/x", arr, global_shape=(n * n_ranks,),
                   offset=(n * r,), rank=r)
        wr.end_step()
    wr.close()


def run(w_src=4, w_dst_counts=(1, 2), n_ranks=8, bytes_per_rank=1 * MiB,
        steps=2, codec="zlib", parallel=2):
    print("mode,w_src,w_dst,wall_s,MiB_s,arrays_verified")
    ok = True
    with tmp_io_dir() as d:
        src = d / "src.bp4"
        _write_series(src, n_ranks=n_ranks, bytes_per_rank=bytes_per_rank,
                      steps=steps, codec=codec, w=w_src)
        for w_dst in w_dst_counts:
            for par, tag in ((0, "serial"), (parallel, f"par{parallel}")):
                dst = d / f"dst_{w_dst}_{tag}.bp4"
                with Timer() as t:
                    stats = repack(src, dst, n_writers=w_dst,
                                   parallel=par)
                n = verify_equivalent(src, dst)
                ok = ok and n == steps
                mib = stats["bytes_raw"] / t.dt / MiB
                print(f"{tag},{w_src},{w_dst},{t.dt:.3f},{mib:.0f},{n}")
                emit(f"repack/{codec}/W{w_src}->W{w_dst}/{tag}",
                     t.dt * 1e6 / max(stats['steps'], 1), f"{mib:.0f}MiB/s")
    print(f"\nrepack {'OK' if ok else 'FAILED'}: every output "
          f"byte-equivalent under the reader")
    return ok


if __name__ == "__main__":
    raise SystemExit(0 if run() else 1)
