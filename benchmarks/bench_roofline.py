"""Roofline table from the dry-run artifacts (benchmarks/results/dryrun/)."""
from __future__ import annotations

import json
import pathlib

from benchmarks.common import emit

RESULTS = pathlib.Path(__file__).resolve().parent / "results" / "dryrun"


def run():
    if not RESULTS.exists():
        emit("roofline/missing", 0.0, "run `python -m repro.launch.dryrun --all` first")
        return
    rows = []
    for p in sorted(RESULTS.glob("*.json")):
        d = json.loads(p.read_text())
        if d.get("status") == "skipped":
            emit(f"roofline/{d['arch']}/{d['shape']}/{d['mesh']}", 0.0, "skipped")
            continue
        if d.get("status") != "ok":
            emit(f"roofline/{d['arch']}/{d['shape']}/{d['mesh']}", 0.0,
                 f"ERROR {d.get('error', '')[:60]}")
            continue
        r = d["roofline"]
        step_s = max(r["compute_s"], r["memory_s"], r["collective_s"])
        emit(f"roofline/{d['arch']}/{d['shape']}/{d['mesh']}", step_s * 1e6,
             f"dom={r['dominant']} comp={r['compute_s']*1e3:.1f}ms "
             f"mem={r['memory_s']*1e3:.1f}ms coll={r['collective_s']*1e3:.1f}ms "
             f"useful={r['useful_flops_ratio']:.3f} mfu_bound={r['mfu_bound']:.3f}")


if __name__ == "__main__":
    run()
