"""Paper Fig 6: write throughput vs number of aggregators (N ranks -> M
subfiles) — the interior-optimum curve (peak at a few aggregators per node,
decline at extreme aggregation)."""
from __future__ import annotations

from benchmarks.common import GiB, Timer, emit, tmp_io_dir
from benchmarks.bench_openpmd_io import write_steps
from repro.core.bp_engine import EngineConfig
from repro.core.darshan import MONITOR


def run(n_ranks=128, bytes_per_rank=256 * 1024, steps=2,
        agg_counts=(1, 2, 4, 8, 16, 32, 64, 128), workers=4):
    for m in agg_counts:
        MONITOR.reset()
        cfg = EngineConfig(aggregators=m, codec="none", workers=workers)
        with tmp_io_dir() as d, Timer() as t:
            total = write_steps(d, n_ranks, bytes_per_rank, steps, cfg)
        emit(f"aggregators/M={m}", t.dt * 1e6 / steps,
             f"{total / t.dt / GiB:.3f}GiB/s files={m}")


if __name__ == "__main__":
    run()
