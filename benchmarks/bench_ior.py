"""Paper Fig 4 / Table I: IOR-like synthetic upper bounds.

FilePerProc (-F): every rank streams large sequential blocks to its own
file. Shared: all ranks write disjoint offsets of one file. Both via the
writer thread pool (the 'parallel procs' of this container)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import GiB, Timer, emit, tmp_io_dir
from repro.core.aggregation import WriterPool
from repro.core.darshan import MONITOR, open_file


def run(n_ranks=32, block=1 * 1024 * 1024, blocks_per_rank=8, workers=4):
    payloads = [np.random.default_rng(r).bytes(block)
                for r in range(min(n_ranks, 8))]

    # --- FilePerProc ---------------------------------------------------------
    MONITOR.reset()
    with tmp_io_dir() as d, Timer() as t:
        pool = WriterPool(workers)

        def per_proc(r):
            with open_file(d / f"ior_{r}.dat", "wb", rank=r) as f:
                for b in range(blocks_per_rank):
                    f.write(payloads[r % len(payloads)])
                f.fsync()

        for r in range(n_ranks):
            pool.submit(per_proc, r)
        pool.shutdown()
    total = n_ranks * blocks_per_rank * block
    emit(f"ior/file_per_proc ranks={n_ranks}", t.dt * 1e6 / n_ranks,
         f"{total / t.dt / GiB:.3f}GiB/s")

    # --- Shared file -----------------------------------------------------------
    MONITOR.reset()
    with tmp_io_dir() as d, Timer() as t:
        f = open_file(d / "ior_shared.dat", "wb", rank=0)
        import threading
        lock = threading.Lock()
        pool = WriterPool(workers)

        def shared(r):
            data = payloads[r % len(payloads)]
            for b in range(blocks_per_rank):
                off = (r * blocks_per_rank + b) * block
                with lock:
                    f.seek(off)
                    f.write(data)

        for r in range(n_ranks):
            pool.submit(shared, r)
        pool.shutdown()
        f.fsync()
        f.close()
    emit(f"ior/shared ranks={n_ranks}", t.dt * 1e6 / n_ranks,
         f"{total / t.dt / GiB:.3f}GiB/s")


if __name__ == "__main__":
    run()
