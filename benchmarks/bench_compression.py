"""Paper Fig 7/8 + Table II: codecs (none / blosc / bzip2 / zlib / lossy) x
aggregation — throughput, stored bytes, file counts and sizes — plus the
device-side compression sweep: codec x block x (host | device) over single
chunks, measuring the on-chip bitshuffle precondition (Pallas kernel) + LZ
overlap against the pure-host pipeline."""
from __future__ import annotations

import numpy as np

from benchmarks.bench_openpmd_io import write_steps
from benchmarks.common import GiB, MiB, Timer, emit, tmp_io_dir
from repro.core import compression as C
from repro.core.bp_engine import EngineConfig
from repro.core.darshan import MONITOR


def run(n_ranks=64, bytes_per_rank=512 * 1024, steps=2, workers=4):
    for codec in ("none", "blosc", "bzip2", "zlib", "lossy:1e-5"):
        MONITOR.reset()
        cfg = EngineConfig(aggregators=1, codec=codec, workers=workers)
        with tmp_io_dir() as d, Timer() as t:
            total = write_steps(d, n_ranks, bytes_per_rank, steps, cfg)
            stored = MONITOR.report()["total"]["POSIX_BYTES_WRITTEN"]
            files = sorted((d / "sim.bp4").glob("data.*"))
            sizes = [f.stat().st_size for f in files]
        tag = codec.replace(":", "_")
        emit(f"compression/{tag}+1AGGR", t.dt * 1e6 / steps,
             f"{total / t.dt / GiB:.3f}GiB/s ratio={total / max(stored, 1):.2f} "
             f"files={len(files)} max={max(sizes) / MiB:.2f}MiB")


def _chunk(nbytes: int) -> np.ndarray:
    """Smooth float32 data — compressible like real particle/field data."""
    n = nbytes // 4
    rng = np.random.default_rng(7)
    return np.cumsum(rng.normal(scale=1e-3, size=n).astype(np.float32))


def run_device_sweep(sizes_mib=(1, 4, 16), blocks=(1 * MiB,),
                     codecs=("blosc", "lossy:1e-5"), repeats=4,
                     check_speedup=True):
    """Single-chunk encode sweep: codec x block x (host | device).

    The device arm runs `device_array_payload` — per-block on-chip
    bitshuffle (Pallas, interpret on CPU, same code on TPU) with the host
    LZ stage overlapping each block's async D2H. Asserts the acceptance
    criterion: for blosc chunks >= 4 MiB the device pipeline beats the
    host (numpy shuffle) pipeline."""
    import jax.numpy as jnp
    failures = []
    for size in sizes_mib:
        host_arr = _chunk(size * MiB)
        dev_arr = jnp.asarray(host_arr)
        for block in blocks:
            for codec in codecs:
                # payload parity first (lossless arms must be bit-identical)
                hp = C.array_payload(host_arr, codec, block=block)
                dp, _ = C.device_array_payload(dev_arr, codec, block=block)
                if C.parse_codec(codec)[0] == "blosc" and hp != dp:
                    raise RuntimeError(
                        f"device/host payload mismatch: {codec} {size}MiB")
                th = min(_timed(lambda: C.array_payload(
                    host_arr, codec, block=block)) for _ in range(repeats))
                td = min(_timed(lambda: C.device_array_payload(
                    dev_arr, codec, block=block)) for _ in range(repeats))
                tag = codec.replace(":", "_")
                nb = host_arr.nbytes
                emit(f"compression_device/{tag}/{size}MiB/b{block // MiB}MiB",
                     td * 1e6,
                     f"host={nb / th / GiB:.3f}GiB/s "
                     f"device={nb / td / GiB:.3f}GiB/s "
                     f"speedup={th / td:.2f}x ratio={nb / len(dp):.2f}")
                if (check_speedup and size >= 4
                        and C.parse_codec(codec)[0] == "blosc" and td >= th):
                    failures.append(
                        f"{codec} {size}MiB: device {td * 1e3:.1f}ms not "
                        f"faster than host {th * 1e3:.1f}ms")
    if failures:
        raise RuntimeError("device pipeline lost to host: "
                           + "; ".join(failures))


def _timed(fn) -> float:
    with Timer() as t:
        fn()
    return t.dt


if __name__ == "__main__":
    run()
    run_device_sweep()
