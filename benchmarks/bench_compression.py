"""Paper Fig 7/8 + Table II: codecs (none / blosc / bzip2) x aggregation —
throughput, stored bytes, file counts and sizes."""
from __future__ import annotations

from benchmarks.common import GiB, MiB, Timer, emit, tmp_io_dir
from benchmarks.bench_openpmd_io import write_steps
from repro.core.bp_engine import BpReader, EngineConfig
from repro.core.darshan import MONITOR


def run(n_ranks=64, bytes_per_rank=512 * 1024, steps=2, workers=4):
    for codec in ("none", "blosc", "bzip2", "zlib"):
        MONITOR.reset()
        cfg = EngineConfig(aggregators=1, codec=codec, workers=workers)
        with tmp_io_dir() as d, Timer() as t:
            total = write_steps(d, n_ranks, bytes_per_rank, steps, cfg)
            stored = MONITOR.report()["total"]["POSIX_BYTES_WRITTEN"]
            files = sorted((d / "sim.bp4").glob("data.*"))
            sizes = [f.stat().st_size for f in files]
        emit(f"compression/{codec}+1AGGR", t.dt * 1e6 / steps,
             f"{total / t.dt / GiB:.3f}GiB/s ratio={total / max(stored, 1):.2f} "
             f"files={len(files)} max={max(sizes) / MiB:.2f}MiB")


if __name__ == "__main__":
    run()
