"""Restart-path benchmark (the paper's §VI checkpoint-restart direction +
our elastic extension): checkpoint write, full restore, and elastic
slice-restore cost vs aggregator count. Confirms the paper's observation
that 'checkpoints read very little data' — the read path touches only the
boxes each shard needs."""
from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import GiB, emit, tmp_io_dir
from repro.ckpt.checkpoint import restore_checkpoint, save_checkpoint
from repro.core.bp_engine import BpReader, EngineConfig
from repro.core.darshan import MONITOR


def run(n_leaves=16, leaf_shape=(1024, 512), aggregators=(1, 4)):
    state = {f"w{i:02d}": np.random.default_rng(i).normal(
        size=leaf_shape).astype(np.float32) for i in range(n_leaves)}
    total = sum(a.nbytes for a in state.values())

    for m in aggregators:
        cfg = EngineConfig(aggregators=m, codec="blosc", workers=4)
        with tmp_io_dir() as d:
            t0 = time.perf_counter()
            save_checkpoint(d, state, 1, n_io_ranks=16, engine_config=cfg)
            t_write = time.perf_counter() - t0

            t0 = time.perf_counter()
            back, _ = restore_checkpoint(d, state)
            t_read = time.perf_counter() - t0
            assert np.allclose(back["w00"], state["w00"])

            # elastic slice: one shard of a hypothetical 8-way resharding
            MONITOR.reset()
            t0 = time.perf_counter()
            reader = BpReader(d / "step_00000001.bp4")
            sl = reader.read_var(1, "state/w00", offset=(0, 0),
                                 extent=(leaf_shape[0] // 8, leaf_shape[1]))
            t_slice = time.perf_counter() - t0
            bytes_read = MONITOR.report()["total"]["POSIX_BYTES_READ"]
        emit(f"restart/M={m} write", t_write * 1e6,
             f"{total / t_write / GiB:.3f}GiB/s")
        emit(f"restart/M={m} full_read", t_read * 1e6,
             f"{total / t_read / GiB:.3f}GiB/s")
        emit(f"restart/M={m} elastic_slice", t_slice * 1e6,
             f"read {bytes_read / 2**20:.2f}MiB of {total / 2**20:.0f}MiB")


if __name__ == "__main__":
    run()
