"""Emit the EXPERIMENTS.md §Roofline markdown table from dry-run artifacts."""
from __future__ import annotations

import json
import pathlib

RESULTS = pathlib.Path(__file__).resolve().parent / "results" / "dryrun"
SHAPES = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def main():
    cells = {}
    for p in sorted(RESULTS.glob("*.json")):
        d = json.loads(p.read_text())
        cells[(d["arch"], d["shape"], d["mesh"])] = d
    archs = sorted({k[0] for k in cells})
    print("| arch | shape | mesh | dom | compute_s | memory_s | collective_s "
          "| useful | mfu_bound | args+temp GiB | fits |")
    print("|---|---|---|---|---|---|---|---|---|---|---|")
    for a in archs:
        for s in SHAPES:
            for m in ("single", "multi"):
                d = cells.get((a, s, m))
                if d is None:
                    continue
                if d["status"] == "skipped":
                    if m == "single":
                        print(f"| {a} | {s} | both | — | — | — | — | — | — | — "
                              f"| skip (full attention @500k) |")
                    continue
                if d["status"] != "ok":
                    print(f"| {a} | {s} | {m} | ERROR | | | | | | | |")
                    continue
                r = d["roofline"]
                mem = d["memory_analysis"]
                gib = (mem["argument_bytes"] + mem["temp_bytes"]) / 2**30
                print(f"| {a} | {s} | {m} | {r['dominant'][:4]} "
                      f"| {r['compute_s']:.3f} | {r['memory_s']:.3f} "
                      f"| {r['collective_s']:.3f} | {r['useful_flops_ratio']:.3f} "
                      f"| {r['mfu_bound']:.4f} | {gib:.1f} "
                      f"| {'Y' if gib <= 16 else 'N'} |")


if __name__ == "__main__":
    main()
