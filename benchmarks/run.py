"""Benchmark driver — one section per paper table/figure.

Usage: PYTHONPATH=src python -m benchmarks.run [--quick] [--only NAME]
Emits ``name,us_per_call,derived`` CSV rows.
"""
from __future__ import annotations

import argparse
import sys


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="substring filter on benchmark module name")
    ap.add_argument("--quick", action="store_true",
                    help="smaller rank counts / payloads")
    args = ap.parse_args(argv)

    from benchmarks import (bench_aggregators, bench_compression,
                            bench_darshan_costs, bench_ior, bench_kernels,
                            bench_openpmd_io, bench_original_io,
                            bench_perf_io, bench_restart, bench_roofline,
                            bench_striping)

    quick = args.quick
    sections = [
        ("original_io", lambda: bench_original_io.run(
            rank_counts=(4, 16, 64) if quick else (4, 16, 64, 256))),
        ("openpmd_io", lambda: bench_openpmd_io.run(
            rank_counts=(4, 16, 64) if quick else (4, 16, 64, 256))),
        ("ior", lambda: bench_ior.run(n_ranks=8 if quick else 32)),
        ("darshan_costs", lambda: bench_darshan_costs.run(
            n_ranks=16 if quick else 256, dumps=3 if quick else 5)),
        ("aggregators", lambda: bench_aggregators.run(
            n_ranks=32 if quick else 128,
            agg_counts=(1, 4, 16, 32) if quick else (1, 2, 4, 8, 16, 32, 64, 128))),
        ("compression", lambda: bench_compression.run(
            n_ranks=16 if quick else 64)),
        ("striping", lambda: bench_striping.run(
            n_ranks=16 if quick else 64,
            counts=(1, 4) if quick else (1, 2, 4, 8))),
        ("kernels", bench_kernels.run),
        ("perf_io", bench_perf_io.run),
        ("restart", bench_restart.run),
        ("roofline", bench_roofline.run),
    ]
    print("name,us_per_call,derived")
    for name, fn in sections:
        if args.only and args.only not in name:
            continue
        try:
            fn()
        except Exception as e:   # noqa: BLE001 — keep the suite running
            print(f"{name}/ERROR,0,{e!r}", file=sys.stderr)
            raise


if __name__ == "__main__":
    main()
