"""Benchmark driver — one section per paper table/figure.

Usage: PYTHONPATH=src python -m benchmarks.run [--quick] [--only NAME]
           [--json PATH]
Emits ``name,us_per_call,derived`` CSV rows; --json additionally dumps the
collected rows as a JSON document (the CI artifact).
"""
from __future__ import annotations

import argparse
import json
import sys


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="substring filter on benchmark module name")
    ap.add_argument("--quick", action="store_true",
                    help="smaller rank counts / payloads")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write collected rows as JSON")
    args = ap.parse_args(argv)

    from benchmarks import (bench_aggregators, bench_async_io,
                            bench_compression, bench_darshan_costs,
                            bench_insitu, bench_ior, bench_jbpd,
                            bench_kernels, bench_openpmd_io,
                            bench_original_io, bench_parallel_io,
                            bench_perf_io, bench_reader_pool, bench_repack,
                            bench_restart, bench_roofline, bench_striping)

    quick = args.quick
    sections = [
        ("original_io", lambda: bench_original_io.run(
            rank_counts=(4, 16, 64) if quick else (4, 16, 64, 256))),
        ("openpmd_io", lambda: bench_openpmd_io.run(
            rank_counts=(4, 16, 64) if quick else (4, 16, 64, 256))),
        ("ior", lambda: bench_ior.run(n_ranks=8 if quick else 32)),
        ("darshan_costs", lambda: bench_darshan_costs.run(
            n_ranks=16 if quick else 256, dumps=3 if quick else 5)),
        ("darshan_dxt_overhead", lambda: bench_darshan_costs.run_tracing_overhead(
            n_ranks=8 if quick else 16, trials=3 if quick else 5)),
        ("darshan_dxt_overhead_device",
         lambda: bench_darshan_costs.run_tracing_overhead(
            n_ranks=8 if quick else 16, trials=3 if quick else 5,
            device=True)),
        ("aggregators", lambda: bench_aggregators.run(
            n_ranks=32 if quick else 128,
            agg_counts=(1, 4, 16, 32) if quick else (1, 2, 4, 8, 16, 32, 64, 128))),
        ("compression", lambda: bench_compression.run(
            n_ranks=16 if quick else 64)),
        ("compression_device", lambda: bench_compression.run_device_sweep(
            sizes_mib=(1, 4) if quick else (1, 4, 16),
            codecs=("blosc",) if quick else ("blosc", "lossy:1e-5"))),
        ("striping", lambda: bench_striping.run(
            n_ranks=16 if quick else 64,
            counts=(1, 4) if quick else (1, 2, 4, 8))),
        ("async_io", lambda: bench_async_io.run(
            steps=4 if quick else 8, repeats=2 if quick else 5,
            codecs=("none",) if quick else ("none", "blosc"),
            aggregator_counts=(1,) if quick else (1, 4))),
        ("parallel_io", lambda: bench_parallel_io.run(
            writer_counts=(1, 2) if quick else (1, 2, 4),
            bytes_per_rank=1 * 1024**2 if quick else 2 * 1024**2,
            steps=3 if quick else 4, repeats=2 if quick else 3)),
        ("parallel_transport", lambda: bench_parallel_io.run_transport_sweep(
            writer_counts=(2,) if quick else (1, 2, 4),
            chunk_sizes=((64 * 1024, 4 * 1024**2, 16 * 1024**2) if quick
                         else (64 * 1024, 1024**2, 4 * 1024**2,
                               16 * 1024**2, 64 * 1024**2)),
            steps=3, repeats=2)),
        ("reader_pool", lambda: bench_reader_pool.run(
            parallel_counts=(1, 2) if quick else (1, 2, 4),
            bytes_per_rank=1 * 1024**2 if quick else 2 * 1024**2,
            steps=2 if quick else 3, repeats=2 if quick else 3)),
        ("jbpd", lambda: bench_jbpd.run(
            n_clients=4, bytes_per_rank=1 * 1024**2 if quick else 2 * 1024**2,
            repeats=4 if quick else 6)),
        ("repack", lambda: bench_repack.run(
            w_dst_counts=(1,) if quick else (1, 2),
            bytes_per_rank=512 * 1024 if quick else 1 * 1024**2,
            steps=2)),
        ("insitu", lambda: bench_insitu.run(
            n_steps=40 if quick else 200, n_ranks=4 if quick else 8,
            n_cells=1024 if quick else 4096)),
        ("kernels", bench_kernels.run),
        ("perf_io", bench_perf_io.run),
        ("restart", bench_restart.run),
        ("roofline", bench_roofline.run),
    ]
    print("name,us_per_call,derived")
    for name, fn in sections:
        if args.only and args.only not in name:
            continue
        try:
            fn()
        except Exception as e:   # noqa: BLE001 — keep the suite running
            print(f"{name}/ERROR,0,{e!r}", file=sys.stderr)
            raise
    if args.json:
        from benchmarks import common
        doc = {"quick": quick, "only": args.only,
               "rows": [{"name": n, "us_per_call": us, "derived": d}
                        for n, us, d in common.ROWS]}
        with open(args.json, "w") as f:
            json.dump(doc, f, indent=1)


if __name__ == "__main__":
    main()
